"""Multi-tenant SpMM serving: many resident operands behind one process.

One serving process rarely hosts ONE sparse operand — it hosts a model
per user, a pruned pattern per checkpoint, a head per task. ``TenantPool``
keeps many ``SpMMEngine``s (one per named operand) behind a single
submit/run surface with an LRU byte budget on device-resident operand
bytes (HBM): when admitting or reviving a tenant would exceed the budget,
the least-recently-used IDLE tenant is evicted — its prepared arrays are
dropped (and the ``ops.prepare_incrs`` memo entry invalidated for raw
InCRS operands) while its constructor-form operand is retained on the
host, so a later request transparently re-preps it. Tenants with queued
or in-flight work are never evicted; if every resident tenant is busy the
pool overcommits and records it (``budget_overcommit``) rather than
dropping work.

Per-launch VMEM footprints (``analysis/vmem.py``) are reported per tenant
by :meth:`TenantPool.vmem_report` — residency is an HBM question, launch
feasibility a VMEM one, and the pool keeps both visible.

``swap_pattern`` works per tenant and stays safe while requests are
queued — it delegates to the engine's swap (in-flight waves finish on the
old operand; a rejected swap leaves queue and operand intact) and updates
the retained host-side operand so a later evict/revive cycle rebuilds the
NEW pattern, not the stale one.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, defaultdict
from typing import Any, Dict, List, Optional

import numpy as np

from .engine import SpMMEngine, SpMMRequest

# Default resident-operand byte budget. Deliberately the ballpark of a
# couple of large prepared operands, not a real HBM size: the pool's job
# is the eviction DISCIPLINE; deployments size this to their part.
DEFAULT_HBM_BUDGET = 256 * 1024 * 1024


def operand_bytes(prep) -> int:
    """Device-resident bytes of one serving operand: the prepared stripe
    arrays (idx + val) for InCRS preps, the packed values (+ index
    metadata) for bound plans. Host-side originals don't count — they are
    what eviction falls back to."""
    total = 0
    idx = getattr(prep, "idx", None)
    if idx is not None:                    # PreparedOperand / sharded
        return int(idx.nbytes) + int(prep.val.nbytes)
    values = getattr(prep, "values", None)
    if values is not None:                 # BoundPlan
        total += int(np.asarray(values).nbytes) if not hasattr(
            values, "nbytes") else int(values.nbytes)
        meta = getattr(getattr(prep, "plan", None), "meta", None)
        fwd = getattr(meta, "fwd_idx", None)
        if fwd is not None:
            total += int(fwd.nbytes)
    return total


@dataclasses.dataclass
class _Tenant:
    name: str
    a: Any                                 # constructor-form operand (host)
    engine_kwargs: Dict[str, Any]
    engine: Optional[SpMMEngine] = None    # None = evicted
    resident_bytes: int = 0
    finished: List[SpMMRequest] = dataclasses.field(default_factory=list)
    evictions: int = 0

    @property
    def resident(self) -> bool:
        return self.engine is not None

    @property
    def busy(self) -> bool:
        """Queued, staged, or in-flight work — never evictable."""
        e = self.engine
        return e is not None and bool(e.queue or e._staged is not None
                                      or e._inflight is not None)


class TenantPool:
    """LRU-budgeted pool of named ``SpMMEngine`` tenants.

    ``engine_kwargs`` passed to :meth:`add` (e.g. ``max_wave_cols``,
    ``latency_budget_us``, ``variant``) are retained and re-applied when
    an evicted tenant is revived, so a tenant's serving configuration
    survives eviction just like its operand does.
    """

    def __init__(self, *, hbm_budget_bytes: int = DEFAULT_HBM_BUDGET,
                 **engine_defaults):
        if hbm_budget_bytes <= 0:
            raise ValueError(f"hbm_budget_bytes must be positive, got "
                             f"{hbm_budget_bytes}")
        self.hbm_budget_bytes = hbm_budget_bytes
        self.engine_defaults = engine_defaults
        # OrderedDict IS the LRU: most-recently-used tenants at the end.
        self._tenants: "OrderedDict[str, _Tenant]" = OrderedDict()
        self.stats: Dict[str, int] = defaultdict(int)

    # -- residency -------------------------------------------------------
    def resident_bytes(self) -> int:
        return sum(t.resident_bytes for t in self._tenants.values()
                   if t.resident)

    def _touch(self, name: str) -> None:
        self._tenants.move_to_end(name)

    def _build_engine(self, tenant: _Tenant) -> None:
        kwargs = dict(self.engine_defaults)
        kwargs.update(tenant.engine_kwargs)
        tenant.engine = SpMMEngine(tenant.a, **kwargs)
        tenant.resident_bytes = operand_bytes(tenant.engine.prep)
        self.stats["builds"] += 1

    def _evict_for(self, incoming: Optional[str]) -> None:
        """Evict idle LRU tenants until the pool fits its budget; a fully
        busy pool overcommits (recorded) instead of dropping work."""
        while self.resident_bytes() > self.hbm_budget_bytes:
            victim = None
            for t in self._tenants.values():         # LRU -> MRU order
                if t.name != incoming and t.resident and not t.busy:
                    victim = t
                    break
            if victim is None:
                self.stats["budget_overcommit"] += 1
                return
            self.evict(victim.name)

    def evict(self, name: str) -> None:
        """Drop a tenant's device-resident operand (its host-side form
        and served results are retained; a later request revives it)."""
        t = self._require(name)
        if not t.resident:
            return
        if t.busy:
            raise ValueError(f"tenant {name!r} has queued or in-flight "
                             f"requests — drain it before evicting")
        t.finished.extend(t.engine.finished)
        # Raw InCRS preps are memoized per live object in ops — dropping
        # the engine alone would keep the stripes alive in that cache.
        if hasattr(t.a, "crs"):
            t.engine._ops.invalidate_prepared(t.a)
        t.engine = None
        t.resident_bytes = 0
        t.evictions += 1
        self.stats["evictions"] += 1

    def _ensure_resident(self, name: str) -> _Tenant:
        t = self._require(name)
        if not t.resident:
            self._build_engine(t)
            self.stats["revivals"] += 1
        self._touch(name)
        self._evict_for(name)
        return t

    def _require(self, name: str) -> _Tenant:
        t = self._tenants.get(name)
        if t is None:
            raise KeyError(f"unknown tenant {name!r}; resident/known: "
                           f"{list(self._tenants)}")
        return t

    # -- tenant surface --------------------------------------------------
    def add(self, name: str, a, **engine_kwargs) -> SpMMEngine:
        """Register (and build) a tenant. ``a`` and ``engine_kwargs``
        accept everything ``SpMMEngine`` does; both are retained so the
        tenant can be revived after eviction."""
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already exists — use "
                             f"swap_pattern to change its operand")
        t = _Tenant(name=name, a=a, engine_kwargs=engine_kwargs)
        self._tenants[name] = t
        self._build_engine(t)
        self._touch(name)
        self._evict_for(name)
        return t.engine

    def submit(self, name: str, req: SpMMRequest) -> None:
        t = self._ensure_resident(name)
        t.engine.submit(req)

    def swap_pattern(self, name: str, a, **kwargs) -> None:
        """Swap one tenant's operand (engine semantics: queued work is
        safe, rejected swaps roll back). On success the retained
        host-side operand is updated too, so an evict/revive cycle
        rebuilds the new pattern."""
        t = self._ensure_resident(name)
        t.engine.swap_pattern(a, **kwargs)
        t.a = a
        t.resident_bytes = operand_bytes(t.engine.prep)
        self._evict_for(name)

    def run(self, name: Optional[str] = None) -> List[SpMMRequest]:
        """Drain one tenant (``name``) or every tenant's queue. Across
        tenants, the next wave goes to the engine whose head request has
        waited longest — no tenant starves because another is chatty."""
        if name is not None:
            t = self._ensure_resident(name)
            return t.engine.run()
        served: List[SpMMRequest] = []
        while True:
            busy = [t for t in self._tenants.values() if t.busy]
            if not busy:
                break
            t = min(busy, key=_head_wait_key)
            before = len(t.engine.finished)
            t.engine.step()
            served.extend(t.engine.finished[before:])
            self._touch(t.name)
        return served

    def results(self, name: str) -> List[SpMMRequest]:
        """Everything this tenant ever served (across evictions)."""
        t = self._require(name)
        out = list(t.finished)
        if t.resident:
            out.extend(t.engine.finished)
        return out

    def engine(self, name: str) -> SpMMEngine:
        """The tenant's live engine (reviving it if evicted)."""
        return self._ensure_resident(name).engine

    # -- reporting -------------------------------------------------------
    def tenants(self) -> List[str]:
        return list(self._tenants)

    def summary(self) -> Dict[str, Any]:
        per_tenant = {}
        for t in self._tenants.values():
            row: Dict[str, Any] = {
                "resident": t.resident,
                "resident_bytes": t.resident_bytes,
                "evictions": t.evictions,
            }
            if t.resident:
                row["engine"] = t.engine.stats_summary()
            per_tenant[t.name] = row
        return {
            "hbm_budget_bytes": self.hbm_budget_bytes,
            "resident_bytes": self.resident_bytes(),
            "n_tenants": len(self._tenants),
            "n_resident": sum(t.resident for t in self._tenants.values()),
            "stats": dict(self.stats),
            "tenants": per_tenant,
        }

    def vmem_report(self) -> Dict[str, Any]:
        """Per-launch VMEM footprint of each RESIDENT tenant at its
        engine's wave cap, from the ``analysis.vmem`` model — residency is
        an HBM budget, launch feasibility a VMEM one; this reports the
        latter next to the former."""
        from ..analysis import vmem
        rows = {}
        for t in self._tenants.values():
            if not t.resident:
                continue
            geom = t.engine._operand_geometry()
            if geom is None:
                continue
            m, n_sections, smax, section = geom
            n = t.engine.max_wave_cols
            # Same default col-tile heuristic ops.spmm applies at launch.
            np128 = -(-n // 128) * 128
            tiles = -(-np128 // 512)
            bn = -(-np128 // (tiles * 128)) * 128
            variant = t.engine.variant
            if variant == "auto":
                variant = "expand"         # smallest-footprint bound
            fp = vmem.incrs_footprint(
                variant, m=m, n=n, bm=128, bn=bn, n_sections=n_sections,
                smax=smax, section=section)
            rows[t.name] = {
                "variant": variant,
                "max_wave_cols": n,
                "vmem_bytes": int(fp.total_bytes),
                "hbm_bytes": t.resident_bytes,
            }
        return {"budget_bytes": vmem.vmem_budget(), "tenants": rows}


def _head_wait_key(t: _Tenant) -> float:
    """Sort key: earliest head-of-queue submit time first; tenants with
    only staged/in-flight work (no queue head) come first of all so the
    pipeline drains before new admissions."""
    e = t.engine
    if e.queue:
        head = e.queue[0]
        return head.t_submit if head.t_submit is not None else 0.0
    return float("-inf")


__all__ = ["TenantPool", "operand_bytes", "DEFAULT_HBM_BUDGET"]
