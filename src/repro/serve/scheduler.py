"""Cost-model wave packing for the continuous SpMM serving engine.

The old engine packed waves by a FIXED column count (``max_wave_cols``):
one size had to fit every operand and every machine, and the FIFO scan
stopped at the first request that didn't fit, so one wide request at the
head starved narrower queued requests that would have packed into the
same wave. This module replaces both decisions with measured data:

* :class:`WaveCostModel` — an affine per-launch wall-time estimate
  ``us(cols) = launch_overhead_us + us_per_col * cols``, seeded from the
  autotuner's persisted measurements (``kernels.autotune`` disk cache —
  its keys encode the operand geometry AND the RHS width, its entries
  carry measured µs) or from a committed ``BENCH_kernels.json`` record,
  then refined online by an EWMA over every retired wave. The paper's
  streaming claim is that the mesh is fed continuously because the
  schedule knows the cost of the next step; this is that cost.
* :class:`WavePacker` — turns a LATENCY BUDGET into a wave width through
  the cost model (``target_cols``), and packs the queue up to that width
  with a bounded skip-scan (head-of-line requests that don't fit are
  bypassed, at most ``skip_limit`` per wave, original order preserved)
  so mixed-width queues pack densely without starving anyone.

Both classes are engine-agnostic: they see only objects with a
``b.shape[1]`` column count, so tests drive them with plain stubs.
"""
from __future__ import annotations

import dataclasses
import json
from collections import deque
from typing import Deque, List, Optional, Sequence, Tuple

# Requests narrower than this never make the target smaller: a pathological
# µs/col estimate must not shrink waves below one useful kernel tile.
MIN_TARGET_COLS = 8

# Default bound on how many queued requests one wave may bypass. Small on
# purpose: the scan stays O(wave + skip_limit) and a bypassed request is
# re-examined at the very next wave (it is still at the front).
DEFAULT_SKIP_LIMIT = 8

# EWMA weight of a fresh observation (higher = adapt faster, noisier).
DEFAULT_EWMA = 0.25


def fit_us_per_col(pairs: Sequence[Tuple[int, float]]
                   ) -> Tuple[Optional[float], float]:
    """Fit ``us(cols) = overhead + slope * cols`` to measured
    ``(cols, us)`` points. Returns ``(us_per_col, launch_overhead_us)``;
    ``(None, 0.0)`` when nothing usable was given.

    One point pins the slope through the origin (overhead 0 — a
    conservative over-estimate of µs/col, so packing starts cautious);
    two or more points get a least-squares line with the intercept
    clamped to >= 0 and the slope to > 0 (a non-increasing fit falls
    back to the through-origin estimate of the widest point).
    """
    pts = [(int(c), float(u)) for c, u in pairs if c > 0 and u > 0]
    if not pts:
        return None, 0.0
    if len(pts) == 1:
        c, u = pts[0]
        return u / c, 0.0
    n = len(pts)
    mx = sum(c for c, _ in pts) / n
    my = sum(u for _, u in pts) / n
    sxx = sum((c - mx) ** 2 for c, _ in pts)
    sxy = sum((c - mx) * (u - my) for c, u in pts)
    if sxx <= 0 or sxy <= 0:
        c, u = max(pts)
        return u / c, 0.0
    slope = sxy / sxx
    intercept = max(0.0, my - slope * mx)
    return slope, intercept


@dataclasses.dataclass
class WaveCostModel:
    """Affine launch-cost estimate, seeded offline and refined online.

    ``us_per_col`` is None until either a seed or the first observed wave
    provides one; callers treat that as "no estimate — use the hard cap".
    """
    us_per_col: Optional[float] = None
    launch_overhead_us: float = 0.0
    ewma: float = DEFAULT_EWMA
    n_observed: int = 0
    source: str = "unseeded"

    def predict_us(self, cols: int) -> Optional[float]:
        """Predicted wall µs of one ``cols``-wide wave (None = no data)."""
        if self.us_per_col is None:
            return None
        return self.launch_overhead_us + self.us_per_col * max(0, cols)

    def target_cols(self, budget_us: Optional[float], hard_cap: int) -> int:
        """The widest wave predicted to finish inside ``budget_us``,
        clamped to ``[MIN_TARGET_COLS, hard_cap]`` (the cap is the shape
        the engine's static feasibility check proved — the budget may
        only narrow it, never widen it)."""
        if budget_us is None or self.us_per_col is None \
                or self.us_per_col <= 0:
            return hard_cap
        fit = int((budget_us - self.launch_overhead_us) / self.us_per_col)
        return max(MIN_TARGET_COLS, min(hard_cap, fit))

    def observe(self, cols: int, wall_us: float) -> None:
        """Fold one retired wave's measured wall time into the estimate."""
        if cols <= 0 or wall_us <= 0:
            return
        obs = max(0.0, wall_us - self.launch_overhead_us) / cols
        if obs <= 0:
            return
        if self.us_per_col is None:
            self.us_per_col = obs
        else:
            self.us_per_col = (1.0 - self.ewma) * self.us_per_col \
                + self.ewma * obs
        self.n_observed += 1


# ----------------------------------------------------------------------
# Offline seeds: the measurements this repo already persists.
def seed_from_autotune(padded_rows: int, n_sections: int, smax: int,
                       section: int, backend: str) -> WaveCostModel:
    """Seed a cost model from the autotuner's persisted sweeps for THIS
    operand geometry: every cache entry whose key matches
    ``(padded_rows, n_sections, smax, section, backend)`` contributes a
    measured ``(n_cols, us)`` point. Unseeded model if none match."""
    from ..kernels import autotune
    pairs = []
    for key, cfg in autotune.cached_configs().items():
        parsed = autotune.parse_cache_key(key)
        if parsed is None:
            continue
        if (parsed["padded_rows"], parsed["n_sections"], parsed["smax"],
                parsed["section"], parsed["backend"]) != \
                (padded_rows, n_sections, smax, section, backend):
            continue
        pairs.append((parsed["n_cols"], cfg.measured_us))
    slope, overhead = fit_us_per_col(pairs)
    if slope is None:
        return WaveCostModel()
    return WaveCostModel(slope, overhead,
                         source=f"autotune[{len(pairs)} pts]")


def seed_from_bench(path: str) -> WaveCostModel:
    """Seed a cost model from a committed ``BENCH_kernels.json``: fused
    InCRS rows record their measured µs and RHS width (``cols=N`` in the
    ``derived`` field) — the cheapest µs/col across them is a usable
    machine-level prior even when the operand geometry differs."""
    try:
        with open(path) as f:
            record = json.load(f)
    except (OSError, ValueError):
        return WaveCostModel()
    best: Optional[float] = None
    for row in record.get("rows", []):
        name = str(row.get("name", ""))
        derived = str(row.get("derived", ""))
        if not name.startswith("incrs_spmm") or "cols=" not in derived:
            continue
        try:
            cols = int(derived.split("cols=")[1].split(";")[0])
            us = float(row["us"])
        except (KeyError, IndexError, ValueError):
            continue
        if cols > 0 and us > 0:
            per = us / cols
            best = per if best is None else min(best, per)
    if best is None:
        return WaveCostModel()
    return WaveCostModel(best, 0.0, source=f"bench[{path}]")


def seed_cost_model(padded_rows: Optional[int] = None,
                    n_sections: Optional[int] = None,
                    smax: Optional[int] = None,
                    section: Optional[int] = None,
                    backend: str = "interpret",
                    bench_path: Optional[str] = None) -> WaveCostModel:
    """Best available offline seed: exact-geometry autotune measurements
    first, the bench record as the machine-level fallback, unseeded last
    (the first retired wave then provides the estimate)."""
    if None not in (padded_rows, n_sections, smax, section):
        model = seed_from_autotune(padded_rows, n_sections, smax, section,
                                   backend)
        if model.us_per_col is not None:
            return model
    if bench_path is not None:
        model = seed_from_bench(bench_path)
        if model.us_per_col is not None:
            return model
    return WaveCostModel()


# ----------------------------------------------------------------------
@dataclasses.dataclass
class WavePacker:
    """Latency-aware wave packing over a deque of requests.

    ``budget_us`` — per-wave latency target; None = pack to the hard cap
    (the engine's proven ``max_wave_cols``), i.e. throughput mode.
    ``skip_limit`` — bounded head-of-line bypass: how many non-fitting
    requests one wave may scan past. 0 restores the strict-FIFO
    wave-barrier behaviour (stop at the first request that doesn't fit).
    """
    cost: WaveCostModel = dataclasses.field(default_factory=WaveCostModel)
    budget_us: Optional[float] = None
    skip_limit: int = DEFAULT_SKIP_LIMIT
    last_target: Optional[int] = None

    def target_cols(self, hard_cap: int) -> int:
        target = self.cost.target_cols(self.budget_us, hard_cap)
        self.last_target = target
        return target

    def next_wave(self, queue: Deque, hard_cap: int) -> List:
        """Pop the next wave off ``queue`` (mutating it): requests are
        admitted front-to-back while they fit the target width; at most
        ``skip_limit`` non-fitting requests are bypassed (and restored to
        the front in their original order). A head request wider than the
        dynamic target is admitted alone — the engine's admission split
        guarantees every queued request fits the hard cap."""
        if not queue:
            return []
        target = self.target_cols(hard_cap)
        wave: List = []
        bypassed: List = []
        cols = 0
        skips = 0
        while queue:
            req = queue.popleft()
            width = req.b.shape[1]
            if not wave and width >= target:
                wave.append(req)            # wide head: ship it alone
                cols += width
                break
            if cols + width <= target:
                wave.append(req)
                cols += width
            else:
                bypassed.append(req)
                skips += 1
                if skips >= max(0, self.skip_limit) + (0 if wave else 1):
                    break
        # Bypassed requests return to the FRONT, original order intact —
        # they are first in line for the very next wave (no starvation).
        queue.extendleft(reversed(bypassed))
        return wave

    def observe(self, cols: int, wall_us: float) -> None:
        self.cost.observe(cols, wall_us)


__all__ = [
    "WaveCostModel", "WavePacker", "fit_us_per_col", "seed_from_autotune",
    "seed_from_bench", "seed_cost_model", "MIN_TARGET_COLS",
    "DEFAULT_SKIP_LIMIT",
]
